"""S2 polishing search + joint (p, strategy) budget search (ISSUE 4):
closed-form seed pricing equivalence, ragged kernel groups, exhaustive
tiny-instance equivalence for the order MILP/polish, polish monotonicity,
and the property that ``solve_cached`` never loses to either of the old
single-endpoint policies (S1-at-max-p, S2-only) at the same budget."""
import itertools

import pytest

from repro.core import solver
from repro.core import strategies_s2 as s2
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.sim import ConvLayer
from repro.sim.s2 import run_s2

BIG = HardwareModel(nbop_pe=10 ** 9, size_mem=None)


# --------------------------------------------------------------------- #
# Seed enumeration: closed-form pricing
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("spec", [
    ConvSpec(2, 6, 6, 7, 3, 3),
    ConvSpec(1, 8, 8, 5, 3, 3),
    ConvSpec(4, 7, 7, 6, 3, 3, s_h=2, s_w=2),
    ConvSpec(3, 9, 9, 4, 5, 5),
])
def test_closed_form_pricing_matches_built_strategies(spec):
    """The analytic (objective, peak) of every (order, p, kg) candidate
    must equal the materialised strategy's — including ragged final
    kernel groups and strided specs."""
    for kg in range(1, spec.n_kernels + 1):
        ks = s2._kg_lens(spec.n_kernels, kg)
        for p in (1, 2, 3, spec.num_patches):
            prof = s2._zig_profile(spec, p)
            for order, builder in (("kernel_major", s2.kernel_major),
                                   ("patch_major", s2.patch_major)):
                obj, peak = s2._price_candidate(spec, BIG, prof, ks, order)
                built = builder(spec, p, kg)
                assert obj == pytest.approx(built.objective(BIG))
                assert peak == built.peak_memory_elements()


def test_ragged_kernel_groups_enumerated():
    """Regression: 7 kernels used to admit only kg sizes 1 and 7 (the
    divisors); now e.g. 3+3+1 is a candidate and the ragged builder
    produces exactly that chunking."""
    spec = ConvSpec(2, 6, 6, 7, 3, 3)
    strat = s2.kernel_major(spec, 4, 3)
    assert tuple(len(g) for g in strat.kernel_groups) == (3, 3, 1)
    rep = run_s2(ConvLayer.random(spec, seed=0), BIG, strat)
    assert rep.correct
    # the full enumeration can only improve on the divisor-only one
    full = s2.best_s2(spec, BIG, polish_iters=0, use_milp=False)
    divisors = s2.best_s2(spec, BIG, kg_sizes=[1, 7], polish_iters=0,
                          use_milp=False)
    assert full.objective <= divisors.objective


def test_small_pe_skips_oversized_kernel_groups():
    """A PE too small for a (patch x kernel-group) step skips that kg
    size instead of raising (large ragged sizes hit this first)."""
    spec = ConvSpec(2, 6, 6, 8, 3, 3)
    hw = HardwareModel(nbop_pe=spec.nb_op_value * 3, size_mem=None)
    res = s2.best_s2(spec, hw, polish_iters=0, use_milp=False)
    assert max(len(g) for g in res.strategy.kernel_groups) <= 3


# --------------------------------------------------------------------- #
# Polish + order MILP
# --------------------------------------------------------------------- #

def test_polish_never_worse_and_stays_feasible():
    spec = ConvSpec(2, 8, 8, 7, 3, 3)
    budget = spec.kernel_elements - 1          # S2-only regime
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=budget)
    res = s2.best_s2(spec, hw, polish_iters=800, rng_seed=1)
    assert res.seed_objective is not None
    assert res.objective <= res.seed_objective
    assert res.gain_vs_seed >= 0.0
    assert res.peak_memory <= budget
    rep = run_s2(ConvLayer.random(spec, seed=2), hw, res.strategy)
    assert rep.correct
    assert rep.total_duration == pytest.approx(
        res.strategy.full_duration(hw))
    assert rep.peak_memory <= budget


def test_polish_improves_over_canonical_orders():
    """On a kernel-heavy layer the joint polish must strictly beat the
    best canonical (kernel/patch-major x zigzag) schedule — the S2
    optimality gap this PR closes."""
    spec = ConvSpec(4, 8, 8, 6, 3, 3)
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=spec.kernel_elements - 1)
    res = s2.best_s2(spec, hw, polish_iters=3000, rng_seed=0)
    assert res.objective < res.seed_objective


def _brute_force_best_order(strategy, hw) -> float:
    """Exact minimum objective over ALL schedule orders of the grid."""
    grid = s2._grid_of(strategy)
    assert grid is not None
    pgroups, cells = grid
    st = s2._S2Grid(strategy.spec, hw, pgroups, strategy.kernel_groups,
                    cells, None)
    best = None
    for perm in itertools.permutations(range(len(st.order))):
        st.order = list(perm)
        c = st.cost()
        if best is None or c < best:
            best = c
    return best


@pytest.mark.parametrize("spec,nbop", [
    (ConvSpec(1, 5, 5, 3, 3, 3), 10 ** 9),           # 9 patches, 3 kernels
    (ConvSpec(1, 4, 4, 4, 3, 3), 10 ** 9),           # 4 patches, 4 kernels
    (ConvSpec(2, 4, 4, 2, 3, 3), None),              # PE-capped grid
])
def test_tiny_instances_reach_exhaustive_order_optimum(spec, nbop):
    """On instances small enough for the order MILP (<= 6 patches per
    group schedule, <= 4 kernels), best_s2 must return the exhaustive
    best order of its grid, with the MILP reporting optimality."""
    nbop = nbop or spec.nb_op_value * spec.n_kernels * 2
    hw = HardwareModel(nbop_pe=nbop, size_mem=None)
    res = s2.best_s2(spec, hw, polish_iters=200, rng_seed=0)
    if res.strategy.n_steps <= s2.S2_MILP_MAX_CELLS:
        assert res.milp_status in ("optimal", "feasible", "timeout",
                                   "skipped_not_grid")
    exhaustive = _brute_force_best_order(res.strategy, hw)
    assert res.objective == pytest.approx(exhaustive)


def test_milp_order_handles_asymmetric_memory_feasibility():
    """An order can be feasible while its reverse overflows (the pending
    write-back of the bigger kernel group): the exact directed model must
    keep the feasible direction instead of reporting infeasible."""
    spec = ConvSpec(1, 5, 5, 3, 3, 3)
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=spec.kernel_elements + 40)
    res = s2.best_s2(spec, hw, polish_iters=200, rng_seed=0)
    assert res.milp_status == "optimal"
    assert res.peak_memory <= hw.size_mem


def test_polish_preserves_grid_coverage():
    """Any polished schedule still computes every (patch, kernel) cell
    exactly once (S2Strategy.__post_init__ would raise otherwise) and
    executes correctly through the functional simulator."""
    spec = ConvSpec(2, 7, 7, 5, 3, 3)
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=spec.kernel_elements)
    res = s2.best_s2(spec, hw, polish_iters=1000, rng_seed=3)
    rep = run_s2(ConvLayer.random(spec, seed=4), hw, res.strategy)
    assert rep.correct
    assert rep.total_macs == spec.macs_total


# --------------------------------------------------------------------- #
# Joint (p, strategy) search
# --------------------------------------------------------------------- #

def test_joint_search_never_worse_than_either_endpoint():
    """Property (ISSUE 4): at every budget, solve_cached's full-Def-3
    duration is <= both old endpoints — the S1 solve at the largest
    feasible group size, and the S2 search alone."""
    spec = ConvSpec(4, 10, 10, 12, 3, 3)
    for frac in (0.4, 0.75, 1.0, 1.5, 3.0):
        size_mem = int(spec.kernel_elements * frac)
        hw = HardwareModel(nbop_pe=10 ** 9, size_mem=size_mem)
        solver.solve_cached.cache_clear()
        solver.best_s2_cached.cache_clear()
        p = 8
        joint = solver.solve_cached(spec, p, hw, polish_iters=400,
                                    use_milp=False, polish_restarts=1)
        joint_full = joint.strategy.full_duration(hw)
        assert joint.strategy.peak_footprint_elements() <= size_mem

        endpoints = []
        p_fit = solver.s1_max_feasible_p(spec, p, hw)
        if p_fit is not None:
            s1 = solver.solve(spec, p_fit, hw, polish_iters=400,
                              use_milp=False, polish_restarts=1)
            if s1.strategy.peak_footprint_elements() <= size_mem:
                endpoints.append(s1.strategy.full_duration(hw))
        try:
            s2_only = s2.best_s2(spec, hw)
            endpoints.append(s2_only.strategy.full_duration(hw))
        except ValueError:
            pass
        assert endpoints, "budget admits no endpoint at all"
        assert joint_full <= min(endpoints) + 1e-9


def test_joint_search_unconstrained_path_unchanged():
    """size_mem=None (the paper's Sec-7.1 setting) takes the historical
    S1 path: no S2 comparison, mode stays s1."""
    spec = ConvSpec(2, 8, 8, 4, 3, 3)
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=None)
    solver.solve_cached.cache_clear()
    res = solver.solve_cached(spec, 8, hw, polish_iters=300,
                              use_milp=False)
    assert res.mode == "s1"


def test_s2_fallback_result_reports_polish_stage():
    """The S2 fallback SolveResult now carries the seed objective (so
    gain_vs_seed reflects the polish) and the MILP status."""
    spec = ConvSpec(6, 8, 8, 16, 3, 3)
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=spec.kernel_elements // 2)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    res = solver.solve_cached(spec, 8, hw, polish_iters=400,
                              use_milp=False)
    assert res.mode == "s2"
    assert res.objective <= res.seed_objective
    assert res.gain_vs_seed >= 0.0
