"""sim.s2 against strategies_s2 model outputs (ISSUE 2 satellite):
functional correctness and exact Def-3 duration reconciliation for both
schedule orders, and for ``best_s2`` search results under memory caps."""
import pytest

from repro.core import strategies_s2 as s2
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.sim import ConvLayer
from repro.sim.s2 import run_s2

BIG = HardwareModel(nbop_pe=10 ** 9, size_mem=None)
SPEC = ConvSpec(c_in=2, h_in=7, w_in=7, n_kernels=6, h_k=3, w_k=3)


@pytest.mark.parametrize("builder", [s2.kernel_major, s2.patch_major])
@pytest.mark.parametrize("p,kg", [(1, 1), (3, 2), (4, 3), (25, 6)])
def test_s2_sim_reconciles_model_exactly(builder, p, kg):
    """Simulator-measured Def-3 duration == strategy.full_duration, for
    both the weight-stationary and input-stationary orders."""
    strat = builder(SPEC, p, kg)
    rep = run_s2(ConvLayer.random(SPEC, seed=1), BIG, strat)
    assert rep.correct, rep.max_abs_err
    assert rep.total_duration == pytest.approx(strat.full_duration(BIG),
                                               abs=1e-9)
    assert rep.peak_memory <= strat.peak_footprint_elements()
    assert rep.elements_written == SPEC.num_patches * SPEC.c_out
    assert rep.total_macs == SPEC.macs_total


def test_s2_protocol_write_back_and_first_load():
    """Protocol terms decompose full_duration and bound reuse savings."""
    strat = s2.patch_major(SPEC, 4, 2)
    assert strat.full_duration(BIG) == pytest.approx(
        strat.objective(BIG) + strat.write_back_duration(BIG))
    assert strat.write_back_duration(BIG) == \
        SPEC.num_patches * SPEC.c_out * BIG.t_w
    assert strat.first_load_duration(BIG) == \
        SPEC.all_pixels_mask.bit_count() * BIG.t_l
    assert strat.peak_working_set_elements() <= \
        strat.peak_footprint_elements()


def test_best_s2_results_run_and_reconcile_under_budgets():
    """The searched strategy executes functionally under every cap it was
    selected for, within the budget, at the advertised duration."""
    spec = ConvSpec(2, 6, 6, 8, 3, 3)
    layer = ConvLayer.random(spec)
    for frac in (0.5, 1.0, 2.0):
        budget = int(spec.kernel_elements * frac)
        hw = HardwareModel(nbop_pe=10 ** 9, size_mem=budget)
        res = s2.best_s2(spec, hw)
        rep = run_s2(layer, hw, res.strategy)
        assert rep.correct, (frac, rep.max_abs_err)
        assert rep.peak_memory <= budget
        assert rep.total_duration == pytest.approx(
            res.strategy.full_duration(hw))
        assert res.objective == pytest.approx(res.strategy.objective(hw))
        assert res.peak_memory == res.strategy.peak_footprint_elements()


def test_s2_lower_bound_is_a_lower_bound():
    for builder in (s2.kernel_major, s2.patch_major):
        for kg in (1, 2, 3, 6):
            strat = builder(SPEC, 4, kg)
            assert strat.objective(BIG) >= s2.s2_lower_bound(SPEC, BIG)
