"""Position-dependent halo accounting for edge bands under SAME padding
(ISSUE 5 satellite, ROADMAP item): edge bands skip their padding rows'
first loads, so ``balanced_row_heights`` picks an asymmetric partition —
pinned on a small grid — and the cluster simulator still reconciles the
analytic savings exactly.  ``same_pad=False`` stays bit-exact."""
import pytest

from repro.configs.clusters import make_cluster
from repro.core.conv_spec import ConvSpec
from repro.core.multichip import (balanced_row_heights, band_pad_rows,
                                  band_solve_duration,
                                  plan_multichip_network, same_pad_rows)
from repro.sim import simulate_multichip

FAST = dict(polish_iters=600, polish_restarts=1)
KW = dict(nb_data_reload=2, time_limit=5.0, polish_iters=300,
          use_milp=False, rng_seed=0, polish_restarts=1)

# SAME-padded 8x8 input (stride 1, 3x3 kernel): h_in = 10, h_out = 8,
# one zero row at the top and one at the bottom.
SPEC = ConvSpec(2, 10, 10, 2, 3, 3)
NET = (SPEC, ConvSpec(2, 8, 8, 4, 3, 3))


# --------------------------------------------------------------------- #
# Geometry
# --------------------------------------------------------------------- #

def test_same_pad_rows_split():
    assert same_pad_rows(SPEC) == (1, 1)
    assert same_pad_rows(ConvSpec(1, 12, 12, 1, 5, 5)) == (2, 2)
    # stride covers the kernel: nothing overlaps, no padding assumed
    assert same_pad_rows(ConvSpec(1, 12, 12, 1, 3, 3, s_h=3, s_w=3)) \
        == (0, 0)


def test_band_pad_rows_edges_only():
    """Only bands whose halo-extended window reaches into the padding
    see free rows; interior bands pay full freight."""
    assert band_pad_rows(SPEC, 0, 3) == 1      # window [0, 5): top row
    assert band_pad_rows(SPEC, 3, 5) == 0      # window [3, 7): interior
    assert band_pad_rows(SPEC, 5, 8) == 1      # window [5, 10): bottom
    assert band_pad_rows(SPEC, 0, 8) == 2      # whole map: both rows
    # strided: window [2, 5) of an 11-row input with top pad 0
    strided = ConvSpec(2, 11, 11, 2, 3, 3, s_h=2, s_w=2)
    assert same_pad_rows(strided) == (0, 1)
    assert band_pad_rows(strided, 0, 2) == 0
    assert band_pad_rows(strided, 3, 5) == 1   # window [6, 11): bottom


# --------------------------------------------------------------------- #
# The asymmetric balanced optimum, pinned
# --------------------------------------------------------------------- #

def test_balanced_heights_asymmetric_under_same_pad():
    """3 chips x 8 output rows: the plain DP balances row counts
    [3, 3, 2]; with SAME-padding savings the edge bands are cheaper per
    row, so the optimum gives them the extra rows — [3, 2, 3] — and its
    position-priced max strictly beats the plain partition's."""
    hw = make_cluster(1).chip
    plain = balanced_row_heights(SPEC, hw, 3, 16, KW)
    padded = balanced_row_heights(SPEC, hw, 3, 16, KW, same_pad=True)
    assert plain == [3, 3, 2]
    assert padded == [3, 2, 3]

    def pos_dur(heights):
        out, r0 = [], 0
        for r in heights:
            d = band_solve_duration(SPEC, r, hw, 16, KW)
            save = band_pad_rows(SPEC, r0, r0 + r) * SPEC.w_in * hw.t_l
            out.append(d - save)
            r0 += r
        return max(out)

    assert pos_dur(padded) < pos_dur(plain)


def test_same_pad_off_is_bit_exact():
    """The default path must not move: same plan, same totals."""
    cluster = make_cluster(3)
    a = plan_multichip_network(NET, cluster, modes=("row",),
                               include_single_chip_baseline=False,
                               balance_rows=True, **FAST)
    b = plan_multichip_network(NET, cluster, modes=("row",),
                               include_single_chip_baseline=False,
                               balance_rows=True, same_pad=False, **FAST)
    assert a.total_duration == b.total_duration
    assert all(sa.pad_saved == 0.0
               for lp in a.layers for sa in lp.shards)


# --------------------------------------------------------------------- #
# Plan-level accounting and simulator reconciliation
# --------------------------------------------------------------------- #

def test_same_pad_plan_saves_and_reconciles():
    cluster = make_cluster(3)
    plain = plan_multichip_network(NET, cluster, modes=("row",),
                                   include_single_chip_baseline=False,
                                   balance_rows=True, **FAST)
    padded = plan_multichip_network(NET, cluster, modes=("row",),
                                    include_single_chip_baseline=False,
                                    balance_rows=True, same_pad=True,
                                    **FAST)
    assert padded.total_duration < plain.total_duration
    edge_savings = [s.pad_saved for lp in padded.layers
                    for s in lp.shards if s.pad_saved > 0]
    assert edge_savings, "edge bands should record skipped pad loads"
    # savings never exceed a shard's first-load traffic (the clamp)
    for lp in padded.layers:
        for s in lp.shards:
            assert 0.0 <= s.pad_saved <= \
                s.strategy.first_load_duration(cluster.chip)
            assert s.gross_duration >= 0.0
    # measured == gross + pad_saved, per shard — the simulator checks it
    rep = simulate_multichip(padded)
    assert rep.correct and rep.accounting_exact and rep.peak_within_budget


def test_same_pad_credits_every_mode_consistently():
    """Replicate and channel shards hold the full map, padding rows
    included — they must get the whole-map credit so the mode DP is not
    biased toward row/hybrid sharding."""
    cluster = make_cluster(2)
    top, bot = same_pad_rows(SPEC)
    whole_map = (top + bot) * SPEC.w_in * cluster.chip.t_l
    for mode in ("replicate", "channel"):
        plan = plan_multichip_network(
            NET, cluster, modes=(mode,),
            include_single_chip_baseline=False, same_pad=True, **FAST)
        for lp in plan.layers:
            for s in lp.shards:
                assert s.pad_saved == pytest.approx(
                    min(whole_map if lp.spec is SPEC else
                        sum(same_pad_rows(lp.spec)) * lp.spec.w_in,
                        s.strategy.first_load_duration(cluster.chip)))
        rep = simulate_multichip(plan)
        assert rep.correct and rep.accounting_exact


def test_same_pad_rejects_one_chip_delegation():
    """The 1-chip path reproduces plan_network, which does not model
    padding — a silent accounting discontinuity between n=1 and n=2 is
    worse than an error."""
    with pytest.raises(ValueError, match="same_pad"):
        plan_multichip_network(NET, make_cluster(1), same_pad=True,
                               **FAST)


def test_same_pad_credits_single_chip_baseline():
    """speedup_vs_single_chip must compare consistently-padded
    accountings: the baseline gets the same whole-map credit the
    replicate shards get (clamped to first loads reuse didn't save)."""
    cluster = make_cluster(2)
    plain = plan_multichip_network(NET, cluster, modes=("replicate",),
                                   **FAST)
    padded = plan_multichip_network(NET, cluster, modes=("replicate",),
                                    same_pad=True, **FAST)
    assert padded.single_chip_duration < plain.single_chip_duration
    credit = plain.single_chip_duration - padded.single_chip_duration
    shard_credit = sum(s.pad_saved for lp in padded.layers
                       for s in lp.shards)
    assert 0 < credit <= shard_credit + 1e-9


def test_same_pad_accounting_mutation_detected():
    """Guard the guard: inflating one shard's pad_saved must flip
    accounting_exact."""
    import dataclasses

    plan = plan_multichip_network(NET, make_cluster(3), modes=("row",),
                                  include_single_chip_baseline=False,
                                  balance_rows=True, same_pad=True,
                                  **FAST)
    lp = plan.layers[0]
    bad_shard = dataclasses.replace(lp.shards[0],
                                    pad_saved=lp.shards[0].pad_saved + 5.0)
    bad_layer = dataclasses.replace(lp, shards=(bad_shard,)
                                    + lp.shards[1:])
    bad = dataclasses.replace(plan,
                              layers=(bad_layer,) + plan.layers[1:])
    assert not simulate_multichip(bad).accounting_exact
