"""Simulator property test (hypothesis): every strategy/shape computes the
exact convolution.  Deterministic simulator tests live in
test_simulator_basic.py; this module skips cleanly without hypothesis."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import hilbert, row_by_row, tiled, zigzag
from repro.sim import ConvLayer, System
from repro.sim.functional import reference_conv

HW = HardwareModel(nbop_pe=10**9, size_mem=10**9)


@settings(max_examples=15, deadline=None)
@given(
    c_in=st.integers(1, 3), hw_in=st.integers(4, 8),
    n=st.integers(1, 3), k=st.integers(2, 3),
    stride=st.integers(1, 2), p=st.integers(1, 5),
    builder=st.sampled_from([row_by_row, zigzag, tiled, hilbert]),
    seed=st.integers(0, 5))
def test_property_functional_correct_any_strategy(c_in, hw_in, n, k, stride,
                                                  p, builder, seed):
    """The decomposed execution computes the exact convolution for every
    strategy/shape — the paper's 'functional simulation' check."""
    spec = ConvSpec(c_in, hw_in, hw_in, n, k, k, stride, stride)
    layer = ConvLayer.random(spec, seed=seed)
    rep = System(layer, HW).run(builder(spec, p))
    assert rep.correct, rep.summary()
    np.testing.assert_allclose(rep.output, reference_conv(layer),
                               rtol=1e-4, atol=1e-4)
