"""Simulator (Sec 6) deterministic tests: functional correctness vs
oracles, metric consistency with the formalism, capacity enforcement.
(The any-strategy property test lives in test_simulator.py and needs
hypothesis.)"""
import numpy as np
import pytest

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import run_steps
from repro.core.strategies import row_by_row, zigzag
from repro.sim import ConvLayer, System
from repro.sim.functional import reference_conv, reference_conv_jax
from repro.sim.trace import render_group_grid, render_input_heatmap

HW = HardwareModel(nbop_pe=10**9, size_mem=10**9)


def test_oracles_agree():
    spec = ConvSpec(3, 8, 9, 4, 3, 2, 2, 1)
    layer = ConvLayer.random(spec)
    np.testing.assert_allclose(reference_conv(layer),
                               reference_conv_jax(layer), atol=1e-4)


def test_metrics_match_formalism():
    spec = ConvSpec(2, 6, 6, 2, 3, 3)
    layer = ConvLayer.random(spec)
    strat = zigzag(spec, 3)
    rep = System(layer, HW).run(strat)
    formal = run_steps(strat.to_steps(), spec, HW)
    assert rep.total_duration == formal.total_duration
    # Def 3's size_i^step unions M_{i-1} with the new loads *before* frees,
    # so it upper-bounds the actual footprint of the free-then-load sequence.
    assert rep.peak_footprint <= formal.peak_footprint
    # DRAM reads = pixels loaded * C_in + kernel elements
    assert rep.elements_read == (strat.pixels_loaded() * spec.c_in
                                 + spec.kernel_elements)
    assert rep.elements_written == spec.num_patches * spec.c_out
    assert rep.total_macs == spec.macs_total


def test_capacity_overflow_detected():
    spec = ConvSpec(2, 6, 6, 2, 3, 3)
    layer = ConvLayer.random(spec)
    tiny = HardwareModel(nbop_pe=10**9, size_mem=spec.kernel_elements + 5)
    with pytest.raises(MemoryError):
        System(layer, tiny).run(zigzag(spec, 3))


def test_pe_capacity_enforced():
    spec = ConvSpec(2, 6, 6, 2, 3, 3)
    layer = ConvLayer.random(spec)
    small_pe = HardwareModel(nbop_pe=spec.nb_op_value * spec.c_out,
                             size_mem=10**9)
    System(layer, small_pe).run(row_by_row(spec, 1))      # 1 patch ok
    with pytest.raises(Exception):
        System(layer, small_pe).run(row_by_row(spec, 2))  # 2 patches too many


def test_trace_rendering():
    spec = ConvSpec(2, 5, 5, 2, 3, 3)
    strat = zigzag(spec, 2)
    grid = render_group_grid(strat)
    assert "zigzag" in grid and len(grid.splitlines()) == spec.h_out + 1
    heat = render_input_heatmap(strat)
    assert len(heat.splitlines()) == spec.h_in + 1
    layer = ConvLayer.random(spec)
    rep = System(layer, HW).run(strat)
    assert all(t.describe(spec) for t in rep.traces)


def test_solver_strategy_runs_functionally():
    from repro.core import solver
    spec = ConvSpec(1, 6, 6, 1, 3, 3)
    res = solver.solve(spec, p=4, hw=HW, time_limit=5, polish_iters=2000,
                       use_milp=False)
    layer = ConvLayer.random(spec)
    rep = System(layer, HW).run(res.strategy)
    assert rep.correct
