"""Hypothesis property tests of strategy/system invariants.  Deterministic
strategy tests live in test_strategies_basic.py so they run without the
hypothesis extra; this module skips cleanly when it is missing."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import run_steps
from repro.core.strategies import (hilbert, lower_bound, row_by_row,
                                   s1_baseline, tiled, zigzag)

BIG_HW = HardwareModel(nbop_pe=10**9)


def specs():
    return st.builds(
        ConvSpec,
        c_in=st.integers(1, 3),
        h_in=st.integers(3, 9),
        w_in=st.integers(3, 9),
        n_kernels=st.integers(1, 4),
        h_k=st.integers(1, 3),
        w_k=st.integers(1, 3),
        s_h=st.integers(1, 2),
        s_w=st.integers(1, 2),
    ).filter(lambda s: s.h_in >= s.h_k and s.w_in >= s.w_k)


@settings(max_examples=40, deadline=None)
@given(spec=specs(), p=st.integers(1, 6),
       builder=st.sampled_from([row_by_row, zigzag, tiled, hilbert]))
def test_property_every_patch_exactly_once(spec, p, builder):
    strat = builder(spec, p)
    seen = sorted(pid for g in strat.groups for pid in g)
    assert seen == list(range(spec.num_patches))
    assert strat.max_group_size() <= p


@settings(max_examples=30, deadline=None)
@given(spec=specs(), p=st.integers(1, 6),
       builder=st.sampled_from([row_by_row, zigzag, tiled, hilbert]))
def test_property_semantics_execute_and_duration_matches(spec, p, builder):
    """Invariant: Def-16 strategies always execute under the Def-2 semantics,
    memory ends empty, and eq. 15 == t_l*sum|I_slice| + n*t_acc recomputed
    from the raw steps."""
    strat = builder(spec, p)
    res = run_steps(strat.to_steps(), spec, BIG_HW)
    assert res.states[-1].empty
    islice_sum = sum(s.i_slice.bit_count() for s in strat.to_steps())
    assert strat.objective(BIG_HW) == islice_sum + strat.n_steps
    assert strat.pixels_loaded() == islice_sum


@settings(max_examples=30, deadline=None)
@given(spec=specs(), p=st.integers(1, 6))
def test_property_objective_at_least_lower_bound(spec, p):
    for builder in (row_by_row, zigzag, tiled, hilbert):
        assert builder(spec, p).objective(BIG_HW) >= \
            lower_bound(spec, p, BIG_HW)


@settings(max_examples=20, deadline=None)
@given(spec=specs(), p=st.integers(2, 6))
def test_property_grouping_never_worse_than_baseline(spec, p):
    """S1 with groups (paper's extension) dominates S1-baseline (1 patch per
    step) for any heuristic order, since merging consecutive patches can only
    remove steps and increase intra-group reuse."""
    assert row_by_row(spec, p).objective(BIG_HW) <= \
        s1_baseline(spec).objective(BIG_HW)
