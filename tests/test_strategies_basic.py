"""Strategy builders — deterministic tests (no hypothesis dependency, so
they run even when the property-test extras are not installed)."""
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import (GroupedStrategy, best_heuristic, k_min,
                                   row_by_row, tiled, zigzag)

BIG_HW = HardwareModel(nbop_pe=10**9)


def test_zigzag_equals_row_when_group_is_multiple_of_wout():
    """Paper Sec 7.2: 'for group sizes that are a multiple of W_out the
    ZigZag and Row-by-Row strategies are identical' (in duration)."""
    spec = ConvSpec(1, 10, 10, 1, 3, 3)        # W_out = 8
    for mult in (1, 2):
        p = spec.w_out * mult
        assert zigzag(spec, p).objective(BIG_HW) == \
            row_by_row(spec, p).objective(BIG_HW)


def test_zigzag_beats_row_for_small_groups():
    """Paper Sec 7.2: for small group sizes ZigZag outperforms Row-by-Row."""
    spec = ConvSpec(1, 12, 12, 1, 3, 3)
    assert zigzag(spec, 2).objective(BIG_HW) < \
        row_by_row(spec, 2).objective(BIG_HW)


def test_best_heuristic_matches_min():
    spec = ConvSpec(1, 8, 8, 1, 3, 3)
    b = best_heuristic(spec, 3, BIG_HW)
    assert b.objective(BIG_HW) == min(
        zigzag(spec, 3).objective(BIG_HW),
        row_by_row(spec, 3).objective(BIG_HW))


def test_k_min_definition():
    spec = ConvSpec(1, 12, 12, 1, 3, 3)        # |X| = 100
    assert k_min(spec, 4) == 25
    assert k_min(spec, 3) == 34


def test_tiled_beats_rbr_and_zigzag_on_square_budget():
    """Beyond-paper: 2-D tiles minimise halo perimeter, so with p=4 a 2x2
    tile should beat both 1-D heuristics on a large enough input."""
    spec = ConvSpec(1, 12, 12, 1, 3, 3)
    t = tiled(spec, 4).objective(BIG_HW)
    assert t <= zigzag(spec, 4).objective(BIG_HW)
    assert t <= row_by_row(spec, 4).objective(BIG_HW)


def test_duplicate_patch_rejected():
    spec = ConvSpec(1, 4, 4, 1, 3, 3)
    try:
        GroupedStrategy("bad", spec, ((0, 1), (1, 2), (3,)))
    except ValueError:
        return
    raise AssertionError("duplicate patch not rejected")


def test_full_duration_decomposition():
    """full_duration = eq. 15 objective + kernel load + write-back — the
    network planner's per-layer accounting (validated against the Sec-6
    simulator in test_network_planner.py)."""
    spec = ConvSpec(2, 8, 8, 3, 3, 3)
    strat = zigzag(spec, 4)
    hw = HardwareModel(nbop_pe=10**9, t_l=2.0, t_w=3.0, t_acc=5.0)
    assert strat.full_duration(hw) == (
        strat.objective(hw)
        + spec.kernel_elements * hw.t_l
        + spec.num_patches * hw.t_w)
    assert strat.peak_footprint_elements() >= (
        spec.kernel_elements + strat.peak_input_footprint() * spec.c_in)
