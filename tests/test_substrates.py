"""Substrate tests: data pipeline determinism/elasticity, checkpoint
atomicity + elastic restore, fault-tolerance runtime, gradient compression,
optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, SyntheticLM
from repro.optim import adamw
from repro.optim.compression import ErrorFeedbackInt8, RandomK
from repro.runtime import fault_tolerance as ft


# ------------------------------ data ---------------------------------- #

def test_pipeline_deterministic_across_restarts():
    src = SyntheticLM(vocab=1000, seed=7)
    cfg = DataConfig(global_batch=8, seq_len=64, data_shards=2)
    p1 = Pipeline(src, cfg, shard=0)
    batches = [p1.next() for _ in range(3)]
    p2 = Pipeline(src, cfg, shard=0)
    p2.restore({"step": 2, "shard": 0})
    np.testing.assert_array_equal(p2.next()["tokens"],
                                  batches[2]["tokens"])


def test_pipeline_shards_disjoint():
    src = SyntheticLM(vocab=1000)
    cfg = DataConfig(global_batch=8, seq_len=32, data_shards=4)
    rows = [Pipeline(src, cfg, shard=s).next()["tokens"]
            for s in range(4)]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(rows[i], rows[j])


def test_pipeline_elastic_rescale_exactly_once():
    """Rescaling 4 shards -> 2 shards at step k: the union of rows consumed
    per step is a pure function of (step, nshards), so no step is ever
    double-consumed after a rescale."""
    src = SyntheticLM(vocab=100, seed=3)
    cfg4 = DataConfig(global_batch=8, seq_len=16, data_shards=4)
    cfg2 = DataConfig(global_batch=8, seq_len=16, data_shards=2)
    a = Pipeline(src, cfg2, shard=0)
    a.restore({"step": 5, "shard": 0}, new_shard=0, new_nshards=2)
    b = Pipeline(src, cfg2, shard=0, start_step=5)
    np.testing.assert_array_equal(a.next()["tokens"], b.next()["tokens"])


def test_labels_are_shifted_tokens():
    src = SyntheticLM(vocab=50)
    p = Pipeline(src, DataConfig(global_batch=2, seq_len=16))
    b = p.next()
    assert b["tokens"].shape == b["labels"].shape == (2, 16)


# --------------------------- checkpoint -------------------------------- #

def _state(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "opt": {"m": jnp.ones((8, 4)), "step": jnp.int32(3)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(10, st, extra={"loss": 1.5})
    got, meta = mgr.restore(10, st)
    np.testing.assert_allclose(got["w"], st["w"])
    assert meta["extra"]["loss"] == 1.5


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    mgr.wait()
    assert mgr.committed_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_uncommitted_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, _state())
    # a crashed half-write: directory without COMMIT
    os.makedirs(tmp_path / "step_9")
    assert mgr.latest_step() == 5
    with pytest.raises(FileNotFoundError):
        mgr.restore(9, _state())


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with explicit shardings (the elastic path)."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(1, st)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), st)
    got, _ = mgr.restore(1, st, shardings=sh)
    assert got["w"].sharding == jax.sharding.SingleDeviceSharding(dev)


# ------------------------- fault tolerance ----------------------------- #

def test_heartbeat_detects_dead_host():
    t = [0.0]
    hb = ft.HeartbeatTracker([0, 1, 2], timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    hb.beat(0, 1)
    hb.beat(1, 1)
    t[0] = 12.0
    assert hb.dead_hosts() == [2]
    assert hb.alive_hosts() == [0, 1]


def test_straggler_detection():
    sd = ft.StragglerDetector([0, 1, 2, 3], warmup=2)
    for _ in range(5):
        for h in (0, 1, 2):
            sd.record(h, 1.0)
        sd.record(3, 3.0)
    assert sd.stragglers() == [3]


def test_plan_rescale_power_of_two():
    plan = ft.plan_rescale(range(64), model_shards=16, chips_per_host=4)
    assert plan.data_shards == 16 and plan.world == 256
    plan2 = ft.plan_rescale(range(60), model_shards=16, chips_per_host=4)
    assert plan2.data_shards == 8            # 240 chips -> 8x16=128 used
    assert plan2.world <= 240


def test_supervisor_restart_loop(tmp_path):
    """Kill a host mid-run: supervisor replans the mesh, restores the last
    checkpoint, and completes all steps with a smaller data axis."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    sup = ft.TrainSupervisor(hosts=list(range(8)), model_shards=4,
                             checkpoint_every=5, chips_per_host=4)
    state = {"ckpt_step": 0}
    failures = {"armed": True}

    def run_step(step, plan):
        if step == 7 and failures["armed"]:
            failures["armed"] = False
            raise ft.HostFailure(3)
        return 1.0

    def save(step):
        state["ckpt_step"] = step

    def restore():
        return state["ckpt_step"]

    rep = sup.run(20, run_step, save, restore)
    assert rep.steps_done == 20
    assert rep.restarts == 1
    assert rep.rescales and rep.rescales[0] <= 8


# -------------------------- compression -------------------------------- #

def test_int8_error_feedback_converges():
    """Quantised-gradient SGD with error feedback reaches the same optimum
    on a quadratic as exact SGD (residual carries the rounding error)."""
    comp = ErrorFeedbackInt8()
    w = jnp.array([2.0, -3.0, 1.5])
    target = jnp.array([0.5, 0.25, -1.0])
    state = comp.init({"w": w})
    for _ in range(200):
        g = {"w": 2 * (w - target)}
        q, state = comp.compress(g, state)
        ghat = comp.decompress(q)
        w = w - 0.05 * ghat["w"]
    np.testing.assert_allclose(w, target, atol=1e-2)


def test_int8_quantisation_bounded_error():
    comp = ErrorFeedbackInt8()
    g = {"a": jnp.linspace(-5, 5, 1000)}
    q, _ = comp.compress(g, comp.init(g))
    back = comp.decompress(q)
    assert float(jnp.max(jnp.abs(back["a"] - g["a"]))) <= 5 / 127 + 1e-6


def test_randomk_mass_conserving():
    """Error feedback conserves gradient mass: transmitted + residual ==
    accumulated gradient, and long-run transmitted mean -> true gradient."""
    rk = RandomK(fraction=0.25)
    g = {"a": jnp.ones((4096,))}
    st = rk.init(g, seed=0)
    acc = jnp.zeros((4096,))
    for i in range(40):
        q, st = rk.compress(g, st)
        acc = acc + q["a"]
        total = acc + st["residual"]["a"]
        np.testing.assert_allclose(total, (i + 1) * g["a"], atol=1e-4)
    assert abs(float(acc.mean()) / 40 - 1.0) < 0.15


def test_randomk_converges_quadratic():
    rk = RandomK(fraction=0.3)
    w = jnp.array([2.0, -3.0, 1.5, 0.7])
    target = jnp.array([0.5, 0.25, -1.0, 0.0])
    st = rk.init({"w": w}, seed=1)
    for _ in range(400):
        q, st = rk.compress({"w": 2 * (w - target)}, st)
        w = w - 0.05 * q["w"]
    np.testing.assert_allclose(w, target, atol=5e-2)


# ---------------------------- optimizer -------------------------------- #

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0)
    params = {"w": jnp.array([4.0, -4.0])}
    state = adamw.init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=0.1, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    _, _, gnorm = adamw.update(params, {"w": jnp.full(3, 100.0)}, state,
                               cfg)
    assert float(gnorm) > 100           # reported pre-clip norm
