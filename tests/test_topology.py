"""Topology-general multi-chip planning (ISSUE 5): Topology
parsing/validation and collective pricing, the unidirectional-ring
bit-exact PR-4 regression, biring/torus dominance, the 1xN-torus and
hybrid rx1 / 1xc degeneracies, and per-topology mutation tests of the
2-D shard stitcher.  Hypothesis twins live in test_topology_props.py."""
import dataclasses

import pytest

from repro.configs import tight
from repro.configs.clusters import make_cluster, torus_dims
from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import ClusterModel, HardwareModel, Topology
from repro.core.multichip import (HYBRID_MODES, MODES, hybrid_shard_specs,
                                  ici_schedule, kernel_shard_specs,
                                  mode_alphabet, plan_multichip_network,
                                  row_shard_specs)
from repro.core.network_planner import InfeasibleNetworkError, plan_network
from repro.sim import simulate_multichip

FAST = dict(polish_iters=600, polish_restarts=1)

TIGHT_BUDGET = max(s.kernel_elements for s in tight.LAYERS) // 2

# PR-4 unidirectional-ring totals for tight.LAYERS at TIGHT_BUDGET
# (rng_seed=0, FAST budgets, conftest polish caps): the bit-exact
# regression gate for the topology generalisation.
PR4_RING = {
    # (n_chips, overlap): (total, modes, final_gather, per-layer ici)
    (2, False): (20669.0, "WWKK", 512, [0, 160, 512, 576]),
    (2, True): (15677.0, "WWKK", 512, [0, 160, 512, 576]),
    (4, False): (17529.0, "WWKK", 768, [0, 160, 768, 864]),
    # overlap totals assume WAR-sound halo pricing: a row->row exchange
    # whose receiving bands read the halo before it can arrive is
    # serialised (4 and 8 chips: the L1 bands are too short to hide it)
    (4, True): (12818.0, "WWKK", 768, [0, 160, 768, 864]),
    (8, False): (16209.0, "WWKK", 896, [0, 160, 896, 1008]),
    (8, True): (13173.0, "WWKK", 896, [0, 160, 896, 1008]),
}


def _plan(topology, n_chips=4, overlap=False, specs=tight.LAYERS,
          **kw):
    cluster = make_cluster(n_chips, size_mem=TIGHT_BUDGET,
                           topology=topology)
    return plan_multichip_network(
        specs, cluster, include_single_chip_baseline=False,
        overlap=overlap, balance_rows=overlap, **FAST, **kw)


# --------------------------------------------------------------------- #
# Topology construction and validation
# --------------------------------------------------------------------- #

def test_topology_parse_strings():
    assert Topology.parse("ring") == Topology("ring")
    assert Topology.parse("biring") == Topology("ring", bidirectional=True)
    assert Topology.parse("torus2x4") == Topology(
        "torus", (2, 4), bidirectional=True)
    t = Topology("torus", (4, 2))
    assert Topology.parse(t) is t
    for bad in ("torus2d", "mesh", "torus2x", "ring2"):
        with pytest.raises(ValueError):
            Topology.parse(bad)


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology("torus")                  # needs dims
    with pytest.raises(ValueError):
        Topology("torus", (0, 4))
    with pytest.raises(ValueError):
        Topology("ring", (2, 2))           # ring takes no dims
    with pytest.raises(ValueError):
        Topology("mesh")


def test_cluster_model_topology_validation():
    chip = HardwareModel(nbop_pe=10 ** 9)
    with pytest.raises(ValueError):        # pre-PR-5 regression, kept
        ClusterModel(chip=chip, n_chips=2, topology="torus2d")
    with pytest.raises(ValueError):        # dims must tile n_chips
        ClusterModel(chip=chip, n_chips=6, topology="torus2x2")
    c = ClusterModel(chip=chip, n_chips=4, t_ici=1.0, topology="torus2x2")
    assert c.topo.grid(4) == (2, 2)
    assert "torus" in c.topo.describe()


def test_torus_dims_squarest():
    assert torus_dims(4) == (2, 2)
    assert torus_dims(8) == (2, 4)
    assert torus_dims(16) == (4, 4)
    assert torus_dims(12) == (3, 4)
    assert torus_dims(2) is None           # only the degenerate 1xN
    assert torus_dims(7) is None           # prime


def test_mode_alphabet_per_topology():
    assert mode_alphabet(make_cluster(4)) == MODES
    assert mode_alphabet(make_cluster(4, topology="biring")) == MODES
    assert mode_alphabet(
        make_cluster(4, topology="torus2x2")) == HYBRID_MODES


# --------------------------------------------------------------------- #
# Collective pricing: hand-computed bottleneck-link counts
# --------------------------------------------------------------------- #

def test_ring_collectives_match_pr3_formulas():
    ring = Topology("ring")
    assert ring.gather(4, 1000) == 750          # ceil(A*(n-1)/n)
    assert ring.scatter(4, 1000) == 750
    assert ring.allgather(4, 1000) == 750
    assert ring.reduce_scatter(4, 1000) == 750
    assert ring.all_to_all(4, 1000) == 750
    assert ring.bcast(4, 1000) == 1000          # pipelined broadcast
    assert ring.gather(1, 1000) == 0
    assert ring.bcast(1, 1000) == 0


def test_reduce_scatter_experimental_pricing_pinned():
    """``Topology.reduce_scatter`` is explicitly experimental — no planner
    mode emits it yet (input-channel sharding is ROADMAP work) — but its
    pricing is pinned here so the formula cannot drift before it is wired
    in: the standard ring algorithm's bottleneck equals the gather's on
    every topology shape."""
    for topo in (Topology("ring"), Topology("ring", bidirectional=True),
                 Topology("torus", (2, 2)), Topology("torus", (2, 4))):
        for n in (2, 4, 8):
            for a in (1, 37, 1000):
                assert topo.reduce_scatter(n, a) == topo.gather(n, a)


def test_biring_halves_collectives():
    bi = Topology("ring", bidirectional=True)
    assert bi.gather(4, 1000) == 375            # ceil(750 / 2)
    assert bi.allgather(4, 1000) == 375
    assert bi.bcast(4, 1000) == 500
    assert bi.gather(4, 999) == 375             # ceil(ceil(999*3/4)/2)


def test_torus_collectives_decompose_per_axis():
    t = Topology("torus", (2, 2))               # unidirectional links
    # gather: axis-1 rings funnel each 500-element band row, then the
    # axis-0 ring funnels the full tensor.
    assert t.gather(4, 1000) == 250 + 500
    assert t.bcast(4, 1000) == 2000             # one broadcast per axis
    assert t.allgather_axis1(4, 1000) == 250
    assert t.scatter_axis0(4, 1000) == 500
    assert t.bcast_axis1(4, 1000) == 500
    tb = Topology("torus", (2, 2), bidirectional=True)
    assert tb.gather(4, 1000) == 125 + 250
    assert tb.bcast(4, 1000) == 1000


@pytest.mark.parametrize("bidir", [False, True])
@pytest.mark.parametrize("dims", [(1, 4), (4, 1), (1, 8), (8, 1)])
def test_degenerate_torus_prices_like_ring(dims, bidir):
    """A 1xN (or Nx1) torus IS the N-ring: every collective must price
    identically for any tensor size."""
    n = dims[0] * dims[1]
    torus = Topology("torus", dims, bidirectional=bidir)
    ring = Topology("ring", bidirectional=bidir)
    for a in (1, 7, 64, 999, 12345):
        assert torus.gather(n, a) == ring.gather(n, a)
        assert torus.scatter(n, a) == ring.scatter(n, a)
        assert torus.allgather(n, a) == ring.allgather(n, a)
        assert torus.reduce_scatter(n, a) == ring.reduce_scatter(n, a)
        assert torus.bcast(n, a) == ring.bcast(n, a)


# --------------------------------------------------------------------- #
# PR-4 bit-exact unidirectional-ring regression
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("n_chips,overlap", sorted(PR4_RING))
def test_ring_reproduces_pr4_bit_exactly(n_chips, overlap):
    total, modes, final, ici = PR4_RING[(n_chips, overlap)]
    plan = _plan("ring", n_chips=n_chips, overlap=overlap)
    assert plan.total_duration == total
    assert plan.mode_string == modes
    assert plan.final_gather_elements == final
    assert [lp.ici_elements for lp in plan.layers] == ici


def test_one_chip_delegation_any_topology():
    """n_chips=1 reproduces plan_network exactly whatever the wiring."""
    specs = tight.LAYERS_SMALL
    net = plan_network(list(specs), make_cluster(1).chip, rng_seed=3,
                       **FAST)
    for topology in ("ring", "biring", Topology("torus", (1, 1))):
        mc = plan_multichip_network(
            list(specs), make_cluster(1, topology=topology), rng_seed=3,
            **FAST)
        assert mc.total_duration == net.total_duration


# --------------------------------------------------------------------- #
# Dominance: bidirectional never slower, torus beats the ring
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("overlap", [False, True])
def test_biring_never_slower_than_ring(overlap):
    ring = _plan("ring", overlap=overlap)
    bi = _plan("biring", overlap=overlap)
    assert bi.total_duration <= ring.total_duration
    # fixed mode sequence: the biring re-pricing of the RING's own plan
    # is also never more expensive, layer by layer
    specs = [lp.spec for lp in ring.layers]
    modes = [lp.mode for lp in ring.layers]
    active = [lp.active_chips for lp in ring.layers]
    uni, uni_final = ici_schedule(
        specs, modes, active, make_cluster(4, size_mem=TIGHT_BUDGET))
    bid, bid_final = ici_schedule(
        specs, modes, active,
        make_cluster(4, size_mem=TIGHT_BUDGET, topology="biring"))
    assert all(b <= u for b, u in zip(bid, uni))
    assert bid_final <= uni_final


def test_torus2x2_beats_four_chip_ring_on_tight4():
    """The ISSUE-5 acceptance point: a 2x2 torus (bidirectional links,
    hybrid sharding available) strictly beats the 4-chip ring on the
    tight4 config, under both accounting disciplines."""
    for overlap in (False, True):
        ring = _plan("ring", overlap=overlap)
        torus = _plan("torus2x2", overlap=overlap)
        assert torus.total_duration < ring.total_duration
        rep = simulate_multichip(torus)
        assert rep.correct and rep.accounting_exact \
            and rep.peak_within_budget


def test_torus_overlap_plan_uses_hybrid_and_reconciles():
    plan = _plan("torus2x2", overlap=True)
    assert "H" in plan.mode_string
    hybrid = [lp for lp in plan.layers if lp.mode == "hybrid"]
    assert hybrid and hybrid[0].grid == (2, 2)
    assert len(hybrid[0].shards) == 4
    rep = simulate_multichip(plan)
    assert rep.correct and rep.accounting_exact and rep.peak_within_budget


# --------------------------------------------------------------------- #
# Hybrid degeneracies: rx1 == pure row, 1xc == pure channel
# --------------------------------------------------------------------- #

def _assert_same_plan(a, b):
    assert a.total_duration == b.total_duration
    assert a.final_gather_elements == b.final_gather_elements
    for la, lb in zip(a.layers, b.layers):
        assert la.compute_duration == lb.compute_duration
        assert la.ici_elements == lb.ici_elements
        assert len(la.shards) == len(lb.shards)
        for sa, sb in zip(la.shards, lb.shards):
            assert sa.spec == sb.spec and sa.chip == sb.chip


@pytest.mark.parametrize("dims,pure", [((4, 1), "row"),
                                       ((1, 4), "channel")])
def test_hybrid_trivial_axis_reproduces_pure_mode(dims, pure):
    topo = Topology("torus", dims, bidirectional=True)
    hybrid = _plan(topo, modes=("replicate", "hybrid"))
    plain = _plan(topo, modes=("replicate", pure))
    _assert_same_plan(hybrid, plain)
    rep = simulate_multichip(hybrid)
    assert rep.correct and rep.accounting_exact and rep.peak_within_budget


def test_hybrid_shard_specs_grid_geometry():
    spec = ConvSpec(3, 12, 12, 10, 3, 3)       # h_out = 10
    shards = hybrid_shard_specs(spec, 2, 3)
    assert len(shards) == 6
    assert sorted(c for c, _, _, _ in shards) == list(range(6))
    # bands x kernel groups tile the full output
    rows = {band for _, band, _, _ in shards}
    kers = {kr for _, _, kr, _ in shards}
    assert rows == {(0, 5), (5, 10)}
    assert kers == {(0, 4), (4, 7), (7, 10)}
    for _, (r0, r1), (k0, k1), s in shards:
        assert s.h_out == r1 - r0 and s.n_kernels == k1 - k0
        assert s.h_in == (s.h_out - 1) * spec.s_h + spec.h_k
    # the rx1 / 1xc degeneracies reuse the pure-mode geometry
    assert [(b, s.h_out) for _, b, _, s in hybrid_shard_specs(spec, 4, 1)] \
        == [(b, s.h_out) for _, b, s in row_shard_specs(spec, 4)]
    assert [(k, s.n_kernels) for _, _, k, s in
            hybrid_shard_specs(spec, 1, 4)] \
        == [(k, s.n_kernels) for _, k, s in kernel_shard_specs(spec, 4)]
    with pytest.raises(ValueError, match="hybrid grid"):
        hybrid_shard_specs(spec, 11, 2)        # more bands than rows
    with pytest.raises(ValueError, match="hybrid grid"):
        hybrid_shard_specs(spec, 2, 11)        # more groups than kernels


# --------------------------------------------------------------------- #
# Infeasible grids and errors name the layer and the topology
# --------------------------------------------------------------------- #

def test_infeasible_hybrid_grid_error_names_layer_and_topology():
    """A chip grid with more row bands than output rows is infeasible
    for hybrid sharding; when no other mode is allowed the error must
    say which layer broke and on what wiring (mirrors the PR-3
    InfeasibleNetworkError message regression)."""
    specs = (ConvSpec(1, 6, 6, 8, 3, 3),)      # h_out = 4 < 8 grid rows
    cluster = make_cluster(8, topology="torus8x1")
    with pytest.raises(InfeasibleNetworkError,
                       match=r"layer 0 .*8 chips .*8x1 torus.*"
                             r"rows<=h_out=4"):
        plan_multichip_network(specs, cluster, modes=("hybrid",), **FAST)


def test_infeasible_budget_error_names_topology():
    cluster = make_cluster(4, size_mem=8, topology="torus2x2")
    with pytest.raises(InfeasibleNetworkError,
                       match=r"layer 0 .*size_mem=8.*4 chips .*"
                             r"2x2 torus, bidirectional"):
        plan_multichip_network(tight.LAYERS_SMALL, cluster, **FAST)


def test_hybrid_requires_a_torus():
    with pytest.raises(InfeasibleNetworkError,
                       match=r"unidirectional ring"):
        plan_multichip_network(tight.LAYERS_SMALL, make_cluster(4),
                               modes=("hybrid",), **FAST)


# --------------------------------------------------------------------- #
# Mutation tests: the 2-D stitcher catches corrupted shards on every
# topology preset (guards the guard, like PR 3 did for the 1-D ring)
# --------------------------------------------------------------------- #

def _mutate(plan, li, **replacements):
    lp = plan.layers[li]
    bad_shard = dataclasses.replace(lp.shards[0], **replacements)
    bad_layer = dataclasses.replace(
        lp, shards=(bad_shard,) + lp.shards[1:])
    return dataclasses.replace(
        plan, layers=plan.layers[:li] + (bad_layer,)
        + plan.layers[li + 1:])


@pytest.mark.parametrize("topology", ["ring", "biring", "torus2x2"])
def test_stitcher_catches_corrupt_shards_per_topology(topology):
    """Shift one shard's halo rows / kernel-channel slice: the
    reference-conv comparison must fail for every topology preset and
    every sharded mode the plan uses."""
    plan = _plan(topology, overlap=(topology == "torus2x2"))
    assert simulate_multichip(plan).correct
    checked = set()
    for li, lp in enumerate(plan.layers):
        if lp.mode in ("row", "hybrid") and "rows" not in checked:
            r0, r1 = lp.shards[0].out_rows
            bad = _mutate(plan, li, out_rows=(r0 + 1, r1 + 1))
            assert not simulate_multichip(bad).correct
            checked.add("rows")
        if lp.mode in ("channel", "hybrid") and "kernels" not in checked:
            k0, k1 = lp.shards[0].kernel_range
            bad = _mutate(plan, li, kernel_range=(k0 + 1, k1 + 1))
            assert not simulate_multichip(bad).correct
            checked.add("kernels")
    assert checked == {"rows", "kernels"}, \
        f"{topology} plan {plan.mode_string} exercised {checked} only"


def test_stitcher_catches_corrupt_hybrid_cell_both_axes():
    """An all-hybrid plan: corrupting either axis of one grid cell must
    break the stitched comparison."""
    plan = _plan("torus2x2", modes=("hybrid",))
    assert plan.mode_string == "HHHH"
    assert simulate_multichip(plan).correct
    r0, r1 = plan.layers[1].shards[0].out_rows
    assert not simulate_multichip(
        _mutate(plan, 1, out_rows=(r0 + 1, r1 + 1))).correct
    k0, k1 = plan.layers[1].shards[0].kernel_range
    assert not simulate_multichip(
        _mutate(plan, 1, kernel_range=(k0 + 1, k1 + 1))).correct


# --------------------------------------------------------------------- #
# Determinism across the topology matrix
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("topology", ["biring", "torus2x2"])
def test_deterministic_under_fixed_seed(topology):
    solver.solve_cached.cache_clear()
    a = _plan(topology, rng_seed=11)
    solver.solve_cached.cache_clear()
    b = _plan(topology, rng_seed=11)
    assert a.total_duration == b.total_duration
    assert a.mode_string == b.mode_string
