"""Hypothesis property tests of the topology collective pricing and the
mode-sequence re-pricer (``ici_schedule``).  Deterministic twins live in
test_topology.py so the invariants stay covered without the hypothesis
extra; this module skips cleanly when it is missing.

Pure pricing only — no solver calls — so the search budgets are cheap.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import Topology
from repro.core.multichip import MODES, ici_schedule
from repro.configs.clusters import make_cluster

COLLECTIVES = ("gather", "scatter", "allgather", "reduce_scatter",
               "all_to_all", "bcast")


def tori():
    return st.builds(
        Topology,
        kind=st.just("torus"),
        dims=st.tuples(st.integers(1, 6), st.integers(1, 6)),
        bidirectional=st.booleans())


@given(n=st.integers(1, 32), a=st.integers(1, 10 ** 6),
       bidir=st.booleans())
def test_bidirectional_ring_never_prices_higher(n, a, bidir):
    """Bidirectional links can only help: every collective's bottleneck
    load is <= the unidirectional ring's (and non-negative)."""
    uni = Topology("ring")
    bi = Topology("ring", bidirectional=True)
    for name in COLLECTIVES:
        u, b = getattr(uni, name)(n, a), getattr(bi, name)(n, a)
        assert 0 <= b <= u


@given(topo=tori(), a=st.integers(1, 10 ** 6))
def test_torus_bidirectional_never_prices_higher(topo, a):
    n = topo.dims[0] * topo.dims[1]
    uni = Topology("torus", topo.dims)
    bi = Topology("torus", topo.dims, bidirectional=True)
    for name in COLLECTIVES:
        assert 0 <= getattr(bi, name)(n, a) <= getattr(uni, name)(n, a)


@given(k=st.integers(1, 32), a=st.integers(1, 10 ** 6),
       bidir=st.booleans(), transpose=st.booleans())
def test_degenerate_torus_equals_ring(k, a, bidir, transpose):
    """A 1xN (or Nx1) torus degenerates to the N-ring exactly, for every
    collective and any tensor size."""
    dims = (k, 1) if transpose else (1, k)
    torus = Topology("torus", dims, bidirectional=bidir)
    ring = Topology("ring", bidirectional=bidir)
    for name in COLLECTIVES:
        assert getattr(torus, name)(k, a) == getattr(ring, name)(k, a)


@given(topo=tori(), a=st.integers(1, 10 ** 6))
def test_collectives_monotone_in_tensor_size(topo, a):
    n = topo.dims[0] * topo.dims[1]
    for name in COLLECTIVES:
        f = getattr(topo, name)
        assert f(n, a) <= f(n, a + 1) <= f(n, 2 * a + 2)


def specs():
    return st.builds(
        ConvSpec,
        c_in=st.integers(1, 4),
        h_in=st.integers(5, 12),
        w_in=st.integers(5, 12),
        n_kernels=st.integers(1, 8),
        h_k=st.integers(1, 3),
        w_k=st.integers(1, 3),
        s_h=st.integers(1, 2),
        s_w=st.integers(1, 2))


@settings(max_examples=60, deadline=None)
@given(chain=st.lists(st.tuples(specs(), st.sampled_from(MODES)),
                      min_size=1, max_size=5),
       n_chips=st.sampled_from([2, 4, 8]))
def test_biring_repricing_never_exceeds_ring(chain, n_chips):
    """For ANY mode sequence over any layer chain, the bidirectional
    ring's ICI charges are layerwise <= the unidirectional ring's."""
    layer_specs = [s for s, _ in chain]
    modes = [m for _, m in chain]
    active = [1 if m == "replicate"
              else min(n_chips, s.h_out if m == "row" else s.n_kernels)
              for s, m in chain]
    uni, uni_final = ici_schedule(
        layer_specs, modes, active, make_cluster(n_chips))
    bid, bid_final = ici_schedule(
        layer_specs, modes, active,
        make_cluster(n_chips, topology="biring"))
    assert all(b <= u for b, u in zip(bid, uni))
    assert bid_final <= uni_final
    assert all(b >= 0 for b in bid) and bid_final >= 0


@settings(max_examples=40, deadline=None)
@given(chain=st.lists(st.tuples(specs(), st.sampled_from(MODES)),
                      min_size=1, max_size=4),
       k=st.sampled_from([2, 4, 8]), bidir=st.booleans())
def test_degenerate_torus_schedule_equals_ring_schedule(chain, k, bidir):
    """ici_schedule on a 1xN torus reproduces the N-ring charges exactly
    for any pure-mode sequence."""
    layer_specs = [s for s, _ in chain]
    modes = [m for _, m in chain]
    active = [1 if m == "replicate"
              else min(k, s.h_out if m == "row" else s.n_kernels)
              for s, m in chain]
    ring_topo = Topology("ring", bidirectional=bidir)
    torus_topo = Topology("torus", (1, k), bidirectional=bidir)
    ring = ici_schedule(layer_specs, modes, active,
                        make_cluster(k, topology=ring_topo))
    torus = ici_schedule(layer_specs, modes, active,
                         make_cluster(k, topology=torus_topo))
    assert ring == torus
