"""Static plan verifier (ISSUE 6): every plan the planners emit passes
with zero error-severity diagnostics, every rule family fires on a
hand-corrupted plan, and the ``verify=`` / ``REPRO_VERIFY_PLANS``
postcondition wiring is pinned.  Hypothesis twins live in
``test_verifier_props.py``."""
import dataclasses
import os

import pytest

from repro.analysis import (PlanVerificationError, Severity, verify_steps)
from repro.analysis.verifier import (assert_verified, should_verify,
                                     strategy_floor, verify_multichip_plan,
                                     verify_network_plan)
from repro.configs import tight
from repro.configs.clusters import TOPOLOGY_PRESETS, make_cluster
from repro.configs.networks import NETWORKS
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step
from repro.core.multichip import plan_multichip_network
from repro.core.network_planner import (InfeasibleNetworkError, plan_network)
from repro.core.strategies import row_by_row

HW = HardwareModel(nbop_pe=10 ** 9, size_mem=None)

SMALL_NET = (ConvSpec(1, 10, 10, 2, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3))

TINY = ConvSpec(1, 4, 4, 1, 3, 3)            # 4 patches, 16 pixels

FAST = dict(polish_iters=300, polish_restarts=1)

TIGHT_BUDGET = max(s.kernel_elements for s in tight.LAYERS) // 2


def _plan_small():
    # REPRO_VERIFY_PLANS=1 (conftest) already asserts the postcondition
    return plan_network(SMALL_NET, HW, **FAST)


# --------------------------------------------------------------------- #
# Positive sweep: emitted plans carry zero error diagnostics
# --------------------------------------------------------------------- #

def test_suite_runs_with_verification_enabled():
    """conftest turns the planners' postcondition on for the whole suite:
    every plan any test builds re-checks itself."""
    assert os.environ.get("REPRO_VERIFY_PLANS") == "1"
    assert should_verify(None) is True
    assert should_verify(False) is False


@pytest.mark.parametrize("name", ["tight2", "tight4"])
def test_network_plans_verify_clean_across_budgets(name):
    """Single-chip plans across the S1 -> S2 crossover budgets: the
    verifier's step walk, budget ledger, floors and reuse clamps all hold
    on real planner output."""
    specs = NETWORKS[name]
    checked = 0
    for size_mem in [None] + tight.budget_points(specs):
        hw = HardwareModel(nbop_pe=10 ** 9, size_mem=size_mem)
        try:
            plan = plan_network(specs, hw, **FAST)
        except InfeasibleNetworkError:
            continue
        report = verify_network_plan(plan)
        assert report.ok, report.render()
        assert not report.errors
        assert report.checked_steps > 0
        checked += 1
    assert checked >= 2


@pytest.mark.parametrize("topology", sorted(TOPOLOGY_PRESETS))
def test_multichip_plans_verify_clean(topology):
    """Sharded cluster plans on every preset topology (with the overlap
    and same_pad refinements on): shard grids, ICI conservation and the
    total recomposition all verify."""
    preset = TOPOLOGY_PRESETS[topology]
    cluster = make_cluster(preset.n_chips, size_mem=TIGHT_BUDGET,
                           topology=preset.topo)
    plan = plan_multichip_network(tight.LAYERS, cluster, overlap=True,
                                  same_pad=True, **FAST)
    report = verify_multichip_plan(plan)
    assert report.ok, report.render()
    assert report.checked_layers == len(tight.LAYERS)
    assert plan.n_sharded_layers >= 1       # the sweep exercises shards


def test_one_chip_delegation_verifies():
    cluster = make_cluster(1, size_mem=TIGHT_BUDGET)
    plan = plan_multichip_network(tight.LAYERS_SMALL, cluster, **FAST)
    assert plan.network_plan is not None
    report = verify_multichip_plan(plan)
    assert report.ok, report.render()


def test_assert_verified_returns_report_and_rejects_unknown():
    report = assert_verified(_plan_small())
    assert report.ok
    with pytest.raises(TypeError):
        assert_verified(object())


# --------------------------------------------------------------------- #
# Step-level negative tests: raw corrupted schedules
# --------------------------------------------------------------------- #

def _legal_steps(spec=TINY, p=2):
    return list(row_by_row(spec, p).to_steps())


def test_clean_steps_verify_ok():
    report = verify_steps(TINY, HW, _legal_steps())
    assert report.ok and not report.diagnostics


def test_free_before_load_is_a_semantics_error():
    steps = [Step(f_inp=1)] + _legal_steps()
    report = verify_steps(TINY, HW, steps)
    assert not report.ok
    assert "step/semantics" in report.rules_fired()


def test_compute_without_kernels_resident():
    """S1 Property 1: computing with no kernel loaded is infeasible."""
    pix = TINY.patch_masks[0]
    steps = [Step(i_slice=pix, out=1, group=(0,))]
    report = verify_steps(TINY, HW, steps)
    assert "step/compute" in report.rules_fired()


def test_double_write_back_detected():
    steps = _legal_steps() + [Step(w=1)]
    report = verify_steps(TINY, HW, steps)
    assert not report.ok
    assert "cover/write-exactly-once" in report.rules_fired()


def test_truncated_schedule_misses_coverage():
    steps = _legal_steps()[:-1]
    report = verify_steps(TINY, HW, steps)
    rules = report.rules_fired()
    assert "cover/outputs" in rules
    assert "cover/memory-empty" in rules


def test_over_budget_step_detected():
    tiny_hw = HardwareModel(nbop_pe=10 ** 9, size_mem=TINY.kernel_elements)
    report = verify_steps(TINY, tiny_hw, _legal_steps())
    assert not report.ok
    assert "mem/step-budget" in report.rules_fired()
    d = next(d for d in report.errors if d.rule == "mem/step-budget")
    assert dict(d.data)["size_mem"] == TINY.kernel_elements


def test_bad_kernel_grouping_detected():
    spec = dataclasses.replace(TINY, n_kernels=2)
    report = verify_steps(spec, HW, _legal_steps(spec),
                          kernel_groups=((0,),))   # kernel 1 unassigned
    assert "cover/outputs" in report.rules_fired()


# --------------------------------------------------------------------- #
# Plan-level negative tests: dataclasses.replace-corrupted plans
# --------------------------------------------------------------------- #

def _with_layer(plan, i, **changes):
    layers = list(plan.layers)
    layers[i] = dataclasses.replace(layers[i], **changes)
    return dataclasses.replace(plan, layers=tuple(layers))


def test_corrupt_total_duration_fires_plan_total():
    plan = dataclasses.replace(_plan_small(),
                               total_duration=_plan_small().total_duration + 1)
    report = verify_network_plan(plan)
    assert not report.ok
    assert "plan/total" in report.rules_fired()


def test_corrupt_gross_duration_fires_ledger():
    plan = _plan_small()
    bad = _with_layer(plan, 0,
                      gross_duration=plan.layers[0].gross_duration + 3.0)
    report = verify_network_plan(bad)
    assert "dur/ledger" in report.rules_fired()


def test_duration_below_floor_fires_floor_rule():
    plan = _plan_small()
    floor = strategy_floor(plan.layers[0].strategy, plan.hw)
    bad = _with_layer(plan, 0, gross_duration=floor - 5.0)
    report = verify_network_plan(bad)
    assert "dur/floor" in report.rules_fired()


def test_savings_without_source_fires_clamp():
    plan = _plan_small()
    bad = _with_layer(plan, 0, reuse_input=False, window_rows=0,
                      input_load_saved=1.0)
    report = verify_network_plan(bad)
    assert "reuse/savings-clamp" in report.rules_fired()


def test_unpaired_reuse_fires_pairing():
    plan = _plan_small()
    lp0 = plan.layers[0]
    bad = _with_layer(plan, 0, reuse_output=not lp0.reuse_output)
    report = verify_network_plan(bad)
    assert "reuse/pairing" in report.rules_fired()


def test_bad_row_window_fires_window_rule():
    plan = _plan_small()
    bad = _with_layer(plan, 1, window_rows=plan.layers[1].spec.h_in + 3)
    report = verify_network_plan(bad)
    assert "reuse/window" in report.rules_fired()


def test_postcondition_raises_with_report():
    plan = dataclasses.replace(_plan_small(), total_duration=-1.0)
    with pytest.raises(PlanVerificationError) as exc:
        assert_verified(plan)
    assert "plan/total" in exc.value.report.rules_fired()
    assert exc.value.report.errors


# --------------------------------------------------------------------- #
# Multi-chip negative tests
# --------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def mc_plan():
    cluster = make_cluster(4, size_mem=TIGHT_BUDGET)
    return plan_multichip_network(tight.LAYERS, cluster,
                                  polish_iters=300, polish_restarts=1)


def _row_layer_index(plan):
    for i, lp in enumerate(plan.layers):
        if lp.mode == "row":
            return i
    pytest.skip("no row-sharded layer in this plan")


def test_mc_corrupt_final_gather_fires_conservation(mc_plan):
    bad = dataclasses.replace(
        mc_plan, final_gather_duration=mc_plan.final_gather_duration + 1)
    report = verify_multichip_plan(bad)
    assert "ici/conservation" in report.rules_fired()


def test_mc_corrupt_total_fires_plan_total(mc_plan):
    bad = dataclasses.replace(mc_plan,
                              total_duration=mc_plan.total_duration + 1)
    report = verify_multichip_plan(bad)
    assert "plan/total" in report.rules_fired()


def test_mc_corrupt_ici_elements_fires_conservation(mc_plan):
    i = _row_layer_index(mc_plan)
    bad = _with_layer(mc_plan, i,
                      ici_elements=mc_plan.layers[i].ici_elements + 7)
    report = verify_multichip_plan(bad)
    assert "ici/conservation" in report.rules_fired()


def test_mc_overlapping_bands_fire_tiling(mc_plan):
    i = _row_layer_index(mc_plan)
    lp = mc_plan.layers[i]
    shards = list(lp.shards)
    r0, r1 = shards[0].out_rows
    shards[0] = dataclasses.replace(shards[0], out_rows=(r0 + 1, r1 + 1))
    bad = _with_layer(mc_plan, i, shards=tuple(shards))
    report = verify_multichip_plan(bad)
    assert "shard/band-tiling" in report.rules_fired()


def test_mc_band_outside_input_fires_halo_source(mc_plan):
    i = _row_layer_index(mc_plan)
    lp = mc_plan.layers[i]
    shards = sorted(lp.shards, key=lambda s: s.out_rows)
    last = shards[-1]
    r0, r1 = last.out_rows
    shards[-1] = dataclasses.replace(last, out_rows=(r0 + 2, r1 + 2))
    bad = _with_layer(mc_plan, i, shards=tuple(shards))
    report = verify_multichip_plan(bad)
    assert "shard/halo-source" in report.rules_fired()


def test_mc_corrupt_compute_duration_fires_ledger(mc_plan):
    i = _row_layer_index(mc_plan)
    bad = _with_layer(mc_plan, i,
                      compute_duration=mc_plan.layers[i].compute_duration + 1)
    report = verify_multichip_plan(bad)
    assert "dur/ledger" in report.rules_fired()


def test_mc_sharded_savings_fire_clamp(mc_plan):
    i = _row_layer_index(mc_plan)
    bad = _with_layer(mc_plan, i, savings=0.5)
    report = verify_multichip_plan(bad)
    assert "reuse/savings-clamp" in report.rules_fired()


def test_mc_shard_pad_over_cap_fires_clamp(mc_plan):
    i = _row_layer_index(mc_plan)
    lp = mc_plan.layers[i]
    shards = list(lp.shards)
    shards[0] = dataclasses.replace(shards[0], pad_saved=10 ** 9)
    bad = _with_layer(mc_plan, i, shards=tuple(shards))
    report = verify_multichip_plan(bad)
    assert "shard/pad-clamp" in report.rules_fired()


def test_mc_war_overlap_clean_plan_has_no_finding():
    """The planner only marks a halo stage overlapped after proving its
    bands read the halo late enough, so a solved overlap plan must pass
    the precise WAR check with no ``ici/war-overlap`` diagnostic at
    all — the rule is now a verdict, not an advisory."""
    cluster = make_cluster(4, size_mem=TIGHT_BUDGET)
    plan = plan_multichip_network(tight.LAYERS, cluster, overlap=True,
                                  polish_iters=300, polish_restarts=1)
    report = verify_multichip_plan(plan)
    assert report.ok, report.render()
    assert "ici/war-overlap" not in report.rules_fired()


def test_mc_war_overlap_unsound_flag_is_an_error():
    """Forcing overlap=True onto a halo stage the planner serialised
    (its bands read the halo before the exchange can deliver it) must
    fire ``ici/war-overlap`` as a hard ERROR from the timed-delivery
    model."""
    cluster = make_cluster(4, size_mem=TIGHT_BUDGET)
    plan = plan_multichip_network(tight.LAYERS, cluster, overlap=True,
                                  polish_iters=300, polish_restarts=1)
    serial = [i for i in range(1, plan.n_layers)
              if not plan.layers[i].overlap
              and plan.layers[i].mode == "row"
              and plan.layers[i - 1].mode == "row"
              and plan.layers[i].ici_elements > 0]
    if not serial:
        pytest.skip("every halo stage was provably overlap-safe")
    i = serial[0]
    layers = list(plan.layers)
    layers[i] = dataclasses.replace(layers[i], overlap=True)
    total = sum(lp.duration for lp in layers) + plan.final_gather_duration
    bad = dataclasses.replace(plan, layers=tuple(layers),
                              total_duration=total)
    report = verify_multichip_plan(bad)
    assert "ici/war-overlap" in report.rules_fired()
    assert not report.ok
    sev = [d.severity for d in report.diagnostics
           if d.rule == "ici/war-overlap"]
    assert sev and all(s is Severity.ERROR for s in sev)
