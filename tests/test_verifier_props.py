"""Hypothesis property tests of the static plan verifier's step walk:
every legal S1 schedule verifies clean, the walked Def-3 duration agrees
with the strategy's own accounting, and dropping or duplicating any
write-back is caught.  Deterministic twins live in test_verifier.py so
the invariants stay covered without the hypothesis extra; this module
skips cleanly when it is missing.

Pure symbolic walks over heuristic strategies — no solver calls.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.analysis import verify_steps
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step
from repro.core.strategies import row_by_row, zigzag

HW = HardwareModel(nbop_pe=10 ** 9, size_mem=None)


def specs():
    return st.builds(
        ConvSpec,
        c_in=st.integers(1, 2),
        h_in=st.integers(3, 7),
        w_in=st.integers(3, 7),
        n_kernels=st.integers(1, 3),
        h_k=st.integers(2, 3),
        w_k=st.integers(2, 3),
    ).filter(lambda s: s.h_k <= s.h_in and s.w_k <= s.w_in)


@settings(max_examples=60, deadline=None)
@given(spec=specs(), p=st.integers(1, 4), zig=st.booleans())
def test_heuristic_schedules_verify_clean(spec, p, zig):
    """Any row_by_row / zigzag schedule is a legal step sequence: no
    semantics, coverage or budget diagnostic at unconstrained memory,
    and the walked duration ledger equals the strategy's full Def-3
    duration."""
    strat = (zigzag if zig else row_by_row)(spec, p)
    report = verify_steps(spec, HW, list(strat.to_steps()))
    assert report.ok, report.render()
    assert not report.diagnostics


@settings(max_examples=60, deadline=None)
@given(spec=specs(), p=st.integers(1, 4),
       drop=st.integers(0, 10 ** 6))
def test_dropping_any_step_is_caught(spec, p, drop):
    """Truncating the schedule at any point loses coverage (or leaves
    memory resident): the verifier must never call a partial schedule
    clean."""
    steps = list(row_by_row(spec, p).to_steps())
    steps = steps[:drop % len(steps)]           # strictly shorter
    report = verify_steps(spec, HW, steps)
    assert not report.ok
    rules = report.rules_fired()
    assert "cover/outputs" in rules or "cover/memory-empty" in rules


@settings(max_examples=60, deadline=None)
@given(spec=specs(), p=st.integers(1, 4), extra=st.integers(0, 10 ** 6))
def test_duplicated_write_back_is_caught(spec, p, extra):
    """Re-writing any already-written output unit fires the
    write-exactly-once rule."""
    steps = list(row_by_row(spec, p).to_steps())
    unit = 1 << (extra % spec.num_patches)
    report = verify_steps(spec, HW, steps + [Step(w=unit)])
    assert not report.ok
    assert "cover/write-exactly-once" in report.rules_fired()


@settings(max_examples=40, deadline=None)
@given(spec=specs(), p=st.integers(1, 4))
def test_budget_rule_matches_exact_peak(spec, p):
    """The budget rule is exact: a size_mem equal to the walk's true
    peak occupancy passes; one element less fails with mem/step-budget
    (no false positives, no false negatives)."""
    steps = list(row_by_row(spec, p).to_steps())
    walk_peak = _peak(spec, steps)
    at = HardwareModel(nbop_pe=10 ** 9, size_mem=walk_peak)
    below = HardwareModel(nbop_pe=10 ** 9, size_mem=walk_peak - 1)
    assert verify_steps(spec, at, steps).ok
    report = verify_steps(spec, below, steps)
    assert not report.ok
    assert "mem/step-budget" in report.rules_fired()


def _peak(spec, steps):
    from repro.analysis.verifier import walk_steps
    walk = walk_steps(spec, HW, steps)
    return max(walk.occupancies)
